"""Tests for repro.obs: metrics registry, P² quantiles, tracing, drift."""
import json
import time

import numpy as np
import pytest

from repro.core import aie_arch
from repro.obs import (DEFAULT_PIDS, Counter, DriftMonitor, Gauge, Histogram,
                       MetricsRegistry, P2Quantile, Tracer)
from repro.obs.tracing import load as load_trace


class TestP2Quantile:
    @pytest.mark.parametrize("p", [0.5, 0.9, 0.99])
    @pytest.mark.parametrize("dist", ["uniform", "normal", "lognormal"])
    def test_accuracy_vs_numpy(self, p, dist):
        rng = np.random.default_rng(42)
        xs = {"uniform": lambda: rng.uniform(10.0, 1000.0, 20_000),
              "normal": lambda: rng.normal(500.0, 50.0, 20_000),
              "lognormal": lambda: rng.lognormal(3.0, 0.5, 20_000)}[dist]()
        est = P2Quantile(p)
        for x in xs:
            est.observe(float(x))
        exact = float(np.percentile(xs, 100 * p))
        assert abs(est.value - exact) / exact < 0.01

    def test_small_sample_interpolates(self):
        est = P2Quantile(0.5)
        for x in [1.0, 2.0, 3.0]:
            est.observe(x)
        assert est.value == 2.0

    def test_rejects_bad_p(self):
        with pytest.raises(ValueError):
            P2Quantile(0.0)
        with pytest.raises(ValueError):
            P2Quantile(1.0)


class TestHistogram:
    def test_streaming_quantiles_vs_numpy(self):
        rng = np.random.default_rng(7)
        xs = rng.uniform(50.0, 5000.0, 20_000)
        h = Histogram("lat", ())
        for x in xs:
            h.record(float(x))
        for q in (0.5, 0.9, 0.99):
            exact = float(np.percentile(xs, 100 * q))
            assert abs(h.quantile(q) - exact) / exact < 0.01
        assert h.count == xs.size
        assert h.min == pytest.approx(xs.min())
        assert h.max == pytest.approx(xs.max())
        assert h.mean == pytest.approx(xs.mean())

    def test_bucket_counts_conserve(self):
        h = Histogram("x", ())
        for v in [0.5, 3.0, 42.0, 1e6, 1e12]:   # incl. +Inf overflow
            h.record(v)
        assert sum(h.bucket_counts) == h.count == 5
        assert h.bucket_counts[-1] == 1          # 1e12 beyond last bound

    def test_merge_adds_and_falls_back_to_buckets(self):
        rng = np.random.default_rng(3)
        xs = rng.uniform(100.0, 1000.0, 10_000)
        a, b = Histogram("m", ()), Histogram("m", ())
        for x in xs[:5000]:
            a.record(float(x))
        for x in xs[5000:]:
            b.record(float(x))
        a.merge(b)
        assert a.count == xs.size
        assert a.sum == pytest.approx(xs.sum())
        # P² state is dropped on merge; quantile() must still answer from
        # the merged buckets, within bucket resolution.
        assert a.quantile(0.5) == a.bucket_quantile(0.5)
        exact = float(np.percentile(xs, 50))
        assert abs(a.quantile(0.5) - exact) / exact < 0.15

    def test_merge_rejects_mismatched_buckets(self):
        a = Histogram("m", (), buckets=[1.0, 2.0])
        b = Histogram("m", (), buckets=[1.0, 3.0])
        with pytest.raises(ValueError):
            a.merge(b)


class TestRegistry:
    def test_get_or_create_and_label_order(self):
        reg = MetricsRegistry()
        c1 = reg.counter("hits", {"a": 1, "b": 2})
        c2 = reg.counter("hits", {"b": 2, "a": 1})
        assert c1 is c2
        c1.inc(3)
        assert reg.find("hits", {"b": 2, "a": 1}).value == 3

    def test_kind_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_json_round_trip(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("events", {"tenant": "a"}).inc(7)
        reg.gauge("depth").set(3.5)
        h = reg.histogram("lat_us")
        for v in (10.0, 20.0, 30.0):
            h.record(v)
        snap = json.loads(reg.to_json())
        assert snap["counters"][0]["value"] == 7
        assert snap["gauges"][0]["value"] == 3.5
        assert snap["histograms"][0]["count"] == 3
        p = tmp_path / "m.json"
        reg.save(str(p), extra={"run": "t"})
        on_disk = json.loads(p.read_text())
        assert on_disk["run"] == "t"
        assert on_disk["counters"] == snap["counters"]

    def test_prometheus_exposition(self):
        reg = MetricsRegistry()
        reg.counter("fleet.dispatched", {"tenant": "a"}).inc(4)
        h = reg.histogram("lat.us", buckets=[1.0, 10.0])
        for v in (0.5, 5.0, 50.0):
            h.record(v)
        text = reg.to_prometheus()
        assert '# TYPE fleet_dispatched counter' in text
        assert 'fleet_dispatched{tenant="a"} 4' in text
        # cumulative buckets: le=1 -> 1, le=10 -> 2, +Inf -> 3
        assert 'lat_us_bucket{le="1"} 1' in text
        assert 'lat_us_bucket{le="10"} 2' in text
        assert 'lat_us_bucket{le="+Inf"} 3' in text
        assert 'lat_us_count 3' in text

    def test_merge_registries(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        a.counter("n").inc(2)
        b.counter("n").inc(5)
        a.gauge("g").set(1.0)
        b.gauge("g").set(9.0)
        b.gauge("g").set(8.0)        # more writes -> b wins
        for v in (1.0, 2.0):
            a.histogram("h").record(v)
        for v in (3.0, 4.0):
            b.histogram("h").record(v)
        a.merge(b)
        assert a.find("n").value == 7
        assert a.find("g").value == 8.0
        assert a.find("h").count == 4
        assert a.find("h").sum == pytest.approx(10.0)


class TestTracer:
    def test_lanes_and_metadata(self):
        tr = Tracer()
        tr.span_us("fleet", "r0", "batch", 0.0, 5.0)
        tr.span_us("fleet", "r1", "batch", 1.0, 5.0)
        tr.span_us("dse", "m", "dp", 0.0, 2.0)
        assert tr.pid("fleet") == DEFAULT_PIDS["fleet"]
        names = [e["args"]["name"] for e in tr.events
                 if e["ph"] == "M" and e["name"] == "process_name"]
        assert names == ["fleet", "dse"]
        assert len(tr.spans("fleet")) == 2
        assert len(tr.spans()) == 3

    def test_new_pid_allocates_beyond_defaults(self):
        tr = Tracer()
        assert tr.pid("custom") > max(DEFAULT_PIDS.values())
        assert tr.pid("custom") == tr.pid("custom")

    def test_region_nesting(self):
        tr = Tracer()
        with tr.region("fleet", "dispatch", "outer"):
            with tr.region("fleet", "dispatch", "inner"):
                time.sleep(0.001)
        spans = {e["name"]: e for e in tr.spans("fleet")}
        o, i = spans["outer"], spans["inner"]
        assert o["ts"] <= i["ts"]
        assert o["ts"] + o["dur"] >= i["ts"] + i["dur"]

    def test_save_load_round_trip(self, tmp_path):
        tr = Tracer(meta={"run": "t"})
        tr.span_us("events", "e0", "ev", 1.0, 2.0, cat="c", args={"k": 1})
        p = tmp_path / "trace.json"
        tr.save(str(p))
        data = load_trace(str(p))
        assert data["otherData"]["run"] == "t"
        xs = [e for e in data["traceEvents"] if e["ph"] == "X"]
        assert xs[0]["name"] == "ev" and xs[0]["cat"] == "c"

    def test_load_rejects_negative_span(self, tmp_path):
        p = tmp_path / "bad.json"
        p.write_text(json.dumps({"traceEvents": [
            {"ph": "X", "name": "x", "pid": 1, "tid": 1,
             "ts": -1.0, "dur": 2.0}]}))
        with pytest.raises(ValueError):
            load_trace(str(p))

    def test_chrome_trace_cycle_conversion(self):
        from repro.sim.trace import ChromeTrace
        tr = ChromeTrace()
        tr.span("tiles", "t0", "mm", 0.0, 1250.0)   # 1250 cy @ 1.25 GHz = 1 us
        sp = tr.spans("tiles")[0]
        assert sp["dur"] == pytest.approx(1250.0 * aie_arch.NS_PER_CYCLE
                                          / 1000.0)


class TestDriftMonitor:
    def test_ratio_and_mape(self):
        mon = DriftMonitor()
        mon.expect("a#0", "serve.latency_us", 100.0)
        for v in (98.0, 102.0):
            mon.observe("a#0", "serve.latency_us", v)
        assert mon.ratio("a#0", "serve.latency_us") == pytest.approx(1.0)
        assert mon.mape("serve.latency_us") == pytest.approx(0.0)

    def test_flags_inflated_replica(self):
        mon = DriftMonitor()
        for key, measured in [("a#0", 100.0), ("a#1", 150.0)]:
            mon.expect(key, "serve.latency_us", 100.0)
            mon.observe(key, "serve.latency_us", measured)
        bad = mon.flagged(0.2, "serve.latency_us")
        assert [e.key for e in bad] == ["a#1"]
        assert bad[0].ratio == pytest.approx(1.5)
        assert mon.mape("serve.latency_us") == pytest.approx(0.25)

    def test_observe_before_expect_is_unpopulated(self):
        mon = DriftMonitor()
        mon.observe("k", "m", 5.0)
        assert mon.ratio("k", "m") is None
        assert mon.mape() is None
        s = mon.summary()
        assert s["m"]["entries"]["k"]["measured"] == 5.0
        assert s["m"]["entries"]["k"]["ratio"] is None


class TestDSETelemetry:
    def test_explore_records_counters_and_spans(self):
        from repro.core import dse, layerspec
        reg, tr = MetricsRegistry(), Tracer()
        best = dse.explore(layerspec.jsc_m(), registry=reg, tracer=tr)
        assert best is not None
        evald = reg.find("dse.candidates_evaluated", {"model": "JSC-M"})
        assert evald is not None and evald.value > 0
        phases = {e["name"] for e in tr.spans("dse")}
        assert {"dp", "score"} <= phases
        walltimes = reg.all("dse.walltime_s")
        assert walltimes and all(g.value >= 0 for g in walltimes)


class TestSimTelemetry:
    @pytest.fixture(scope="class")
    def res(self):
        from repro.core import dse, layerspec
        from repro.sim import run as simrun
        design = dse.explore(layerspec.jsc_m())
        return simrun.simulate_placement(
            design.placement, tenant="jsc-m",
            config=simrun.SimConfig(events=2, trace=False))

    def test_export_metrics(self, res):
        reg = res.export_metrics()
        utils = reg.all("sim.resource.utilization")
        assert utils and all(0.0 <= g.value <= 1.0 for g in utils)
        bottlenecks = reg.all("sim.bottleneck.utilization")
        assert len(bottlenecks) == 1
        assert bottlenecks[0].value == pytest.approx(
            max(g.value for g in utils))
        lat = reg.all("sim.event.latency_ns")
        assert lat and lat[0].count == 2
        assert lat[0].mean == pytest.approx(res.latency_ns)

    def test_unified_timeline_sim_plus_wall(self):
        """One ChromeTrace carries cycle-clock sim spans AND wall-clock
        fleet-style spans."""
        from repro.core import dse, layerspec
        from repro.sim import run as simrun
        from repro.sim.trace import ChromeTrace
        tr = ChromeTrace(meta={"test": "unified"})
        design = dse.explore(layerspec.jsc_m())
        simrun.simulate_placement(design.placement, tenant="jsc-m",
                                  config=simrun.SimConfig(events=1),
                                  tracer=tr)
        with tr.region("fleet", "dispatch", "batch"):
            pass
        lanes = {e["args"]["name"] for e in tr.events
                 if e["ph"] == "M" and e["name"] == "process_name"}
        assert "tiles" in lanes and "fleet" in lanes
        assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in tr.spans())


class TestHeavyTailedLoad:
    """obs.metrics under bursty, high-CV open-loop streams: the quantile
    estimators and exporters backing the ``slo.*``/``load.*`` families."""

    def _bursty_gaps(self, cv, n=20_000, seed=9):
        from repro.serve import workload
        ts = workload.arrival_times(workload.burst(1e6, cv), n + 1,
                                    seed=seed)
        return np.diff(np.asarray(ts)) * 1e9        # inter-arrival gaps, ns

    @pytest.mark.parametrize("cv", [2.0, 4.0])
    def test_p2_quantiles_on_bursty_stream(self, cv):
        xs = self._bursty_gaps(cv)
        for p in (0.5, 0.9, 0.99):
            est = P2Quantile(p)
            for x in xs:
                est.observe(float(x))
            exact = float(np.percentile(xs, 100 * p))
            assert abs(est.value - exact) / exact < 0.05, (cv, p)

    def test_histogram_merge_on_bursty_shards(self):
        """Per-replica histograms merged into a fleet view must preserve
        counts, sum, and tail quantiles on a high-CV stream."""
        xs = self._bursty_gaps(4.0)
        shards = [Histogram("w", ()) for _ in range(4)]
        for i, x in enumerate(xs):
            shards[i % 4].record(float(x))
        total = Histogram("w", ())
        for s in shards:
            total.merge(s)
        assert total.count == xs.size
        assert total.sum == pytest.approx(xs.sum())
        assert total.max == pytest.approx(xs.max())
        exact_p99 = float(np.percentile(xs, 99))
        # merge falls back to bucket interpolation -> coarser than P²
        assert abs(total.quantile(0.99) - exact_p99) / exact_p99 < 0.25

    def test_slo_and_load_families_round_trip(self, tmp_path):
        """slo.* / load.* / model.queue.* metrics survive JSON and
        Prometheus export intact."""
        from repro.obs.slo import SLOSpec, SLOTracker
        reg = MetricsRegistry()
        tr = SLOTracker(SLOSpec(tenant="a", p99_latency_budget_ns=1000.0,
                                availability=0.99, window_s=60.0),
                        registry=reg)
        for i in range(20):
            tr.record(2000.0 if i % 4 == 0 else 100.0, t=i * 0.1)
        tr.snapshot(now=2.0)
        reg.counter("load.offered", {"tenant": "a"}).inc(25)
        reg.counter("load.admitted", {"tenant": "a"}).inc(20)
        reg.counter("load.shed", {"tenant": "a"}).inc(5)
        reg.gauge("model.queue.sojourn_p99_ns", {"model": "m"}).set(1234.5)
        snap = json.loads(reg.to_json())
        counters = {(c["name"], c["labels"].get("tenant")): c["value"]
                    for c in snap["counters"]}
        assert counters[("slo.requests.good", "a")] == 15
        assert counters[("slo.requests.bad", "a")] == 5
        assert counters[("load.offered", "a")] == 25
        assert counters[("load.shed", "a")] == 5
        gauges = {g["name"]: g["value"] for g in snap["gauges"]}
        assert gauges["model.queue.sojourn_p99_ns"] == 1234.5
        assert "slo.error_budget.remaining" in gauges
        text = reg.to_prometheus()
        assert 'load_offered{tenant="a"} 25' in text
        assert 'slo_requests_bad{tenant="a"} 5' in text
        assert 'model_queue_sojourn_p99_ns{model="m"} 1234.5' in text

    def test_drift_summary_carries_flagged_and_suspects(self):
        mon = DriftMonitor()
        mon.expect("k1", "model.queue.sojourn_p99_ns", 100.0)
        mon.observe("k1", "model.queue.sojourn_p99_ns", 200.0)
        mon.expect("k2", "model.queue.sojourn_p99_ns", 100.0)
        mon.observe("k2", "model.queue.sojourn_p99_ns", 101.0)
        s = mon.summary(flag_threshold=0.10)
        d = s["model.queue.sojourn_p99_ns"]
        assert d["flagged"] == ["k1"]
        mon.expect("a#0", "model.stage.shim", 100.0)
        mon.observe("a#0", "model.stage.shim", 300.0)
        s2 = mon.summary(flag_threshold=0.10)
        assert s2["model.stage.shim"]["suspects"], \
            "flagged stage metric must name suspect constants"
