"""End-to-end system behaviour tests: serving engine, train auto-resume,
gradient compression, fault-tolerance watchdog."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ckpt as ckpt_lib
from repro import optim
from repro.configs import get_reduced
from repro.data import JetConfig, jet_batch
from repro.distributed import compression
from repro.distributed.ft import StepWatchdog, WatchdogConfig
from repro.distributed.steps import make_train_step
from repro.models import build
from repro.models import deepsets as ds
from repro.serve import JetServer


def _quantize_inputs(x, e_in):
    return np.clip(np.round(x / 2.0 ** e_in), -128, 127).astype(np.int8)


class TestServing:
    def test_fused_server_matches_oracle(self):
        """The deployed fused kernel must be bit-identical to the reference
        engine on the same quantized model (INT8 is exact)."""
        key = jax.random.key(0)
        params = ds.deepsets_init(key, 8, [16, 16], [16, 5])
        x, _ = jet_batch(JetConfig(n_particles=8, n_features=8, n_classes=5),
                         32, 1)
        qphi, qrho = ds.to_quantized(params, x[:16])
        fused = JetServer(qphi, rho=qrho, mode="fused", interpret=True,
                          window_us=50.0)
        ref = JetServer(qphi, rho=qrho, mode="ref", window_us=50.0)
        xq = _quantize_inputs(x, qphi.e_in)
        try:
            for i in range(4):
                a = fused.infer(xq[i])
                b = ref.infer(xq[i])
                np.testing.assert_array_equal(a, b)
        finally:
            fused.close()
            ref.close()

    def test_server_batches_requests(self):
        key = jax.random.key(1)
        params = ds.deepsets_init(key, 8, [16, 16], [16, 5])
        x, _ = jet_batch(JetConfig(n_particles=8, n_features=8, n_classes=5),
                         64, 2)
        qphi, qrho = ds.to_quantized(params, x[:16])
        srv = JetServer(qphi, rho=qrho, mode="ref", max_batch=16,
                        window_us=20_000.0)
        try:
            xq = _quantize_inputs(x, qphi.e_in)
            reqs = [srv.submit(xq[i]) for i in range(16)]
            for r in reqs:
                assert r.event.wait(30)
            assert max(srv.stats.batch_sizes) > 1, "no batching happened"
        finally:
            srv.close()


class TestTrainResume:
    def test_auto_resume_continues_from_checkpoint(self, tmp_path):
        cfg = get_reduced("xlstm-350m")
        model = build(cfg)
        ocfg = optim.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
        step_fn = jax.jit(make_train_step(cfg, ocfg))
        params = model.init(jax.random.key(0))
        opt = optim.init(params)
        batch = {"tokens": jnp.ones((2, 16), jnp.int32),
                 "labels": jnp.ones((2, 16), jnp.int32)}

        for step in range(3):
            params, opt, _ = step_fn(params, opt, batch)
        ckpt_lib.save(str(tmp_path), 3, (params, opt))
        # "crash": restore into same-structure state
        (params2, opt2), step, _ = ckpt_lib.restore(
            str(tmp_path), (params, opt))
        assert step == 3
        assert int(opt2.step) == 3
        a = jax.tree.leaves(params)[0]
        b = jax.tree.leaves(params2)[0]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # training continues from the restored state
        params3, opt3, m = step_fn(params2, opt2, batch)
        assert int(opt3.step) == 4
        assert np.isfinite(float(m["loss"]))

    def test_uncommitted_checkpoint_invisible(self, tmp_path):
        tree = {"w": jnp.ones((4,))}
        d = ckpt_lib.save(str(tmp_path), 1, tree)
        os.remove(os.path.join(d, ckpt_lib.COMMIT))
        assert ckpt_lib.latest_step(str(tmp_path)) is None


class TestGradientCompression:
    def test_error_feedback_preserves_signal(self):
        """Int8+EF compression: the accumulated decompressed signal tracks
        the accumulated true gradient (residual carried, not lost)."""
        rng = np.random.default_rng(0)
        g_true = jnp.asarray(rng.normal(0, 1e-3, (128,)), jnp.float32)
        err = jnp.zeros_like(g_true)
        acc = jnp.zeros_like(g_true)
        s = jnp.float32(1.0)
        for _ in range(50):
            q, s, err = compression.compress(g_true, err)
            acc = acc + compression.decompress(q, s)
        total = 50.0 * g_true
        # the running sum stays within one quantization quantum of truth
        resid = float(jnp.max(jnp.abs(acc - total)))
        assert resid <= float(s) + 1e-6

    @pytest.mark.skipif(
        not hasattr(jax.sharding, "AxisType"),
        reason="jax pin lacks jax.sharding.AxisType / make_mesh axis_types; "
               "reconcile the requirements-dev.txt pin")
    def test_compressed_psum_single_axis(self):
        mesh = jax.make_mesh((1,), ("pod",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        g = {"w": jnp.arange(8, dtype=jnp.float32) * 1e-2}
        e = compression.init_error_state(g)

        def f(g, e):
            return compression.compressed_psum(g, e, "pod")

        g2, _ = jax.jit(jax.shard_map(
            f, mesh=mesh,
            in_specs=(jax.sharding.PartitionSpec(),) * 2,
            out_specs=(jax.sharding.PartitionSpec(),) * 2,
            check_vma=False))(g, e)
        np.testing.assert_allclose(np.asarray(g2["w"]),
                                   np.asarray(g["w"]), atol=1e-3)


class TestWatchdog:
    def test_straggler_counted(self):
        wd = StepWatchdog(WatchdogConfig(straggler_factor=3.0,
                                         min_timeout_s=60.0))
        for _ in range(8):
            with wd.step():
                time.sleep(0.005)
        with wd.step():
            time.sleep(0.1)       # 20x median -> straggler
        assert wd.stragglers >= 1

    def test_hang_handler_fires(self):
        fired = []
        wd = StepWatchdog(WatchdogConfig(min_timeout_s=0.05),
                          on_hang=lambda: fired.append(1))
        with wd.step():
            time.sleep(0.15)
        assert fired
