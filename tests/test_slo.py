"""SLO accounting (repro.obs.slo): spec parsing, error budgets, burn-rate
alerts on deterministic synthetic traces, and the exit-gating report."""
import json

import pytest

from repro.obs import MetricsRegistry
from repro.obs.slo import (BurnWindow, SLOReport, SLOSpec, SLOTracker,
                           default_burn_windows, parse_slo)


def _spec(**kw):
    base = dict(tenant="t", p99_latency_budget_ns=1000.0,
                availability=0.99, window_s=60.0)
    base.update(kw)
    return SLOSpec(**base)


class TestSpecAndParse:
    def test_validation(self):
        with pytest.raises(ValueError):
            _spec(p99_latency_budget_ns=0.0)
        with pytest.raises(ValueError):
            _spec(availability=1.0)
        with pytest.raises(ValueError):
            _spec(availability=0.0)
        with pytest.raises(ValueError):
            _spec(window_s=-1.0)
        assert _spec(availability=0.95).error_budget == pytest.approx(0.05)

    def test_parse_every_tenant_and_overrides(self):
        specs = parse_slo("500:0.95,b=900:0.999", ["a", "b"],
                          budget_scale_ns=1e3)
        assert specs["a"].p99_latency_budget_ns == 500e3
        assert specs["a"].availability == 0.95
        assert specs["b"].p99_latency_budget_ns == 900e3
        assert specs["b"].availability == 0.999

    def test_parse_default_availability_and_scale(self):
        specs = parse_slo("2000", ["x"], budget_scale_ns=1.0)
        assert specs["x"].p99_latency_budget_ns == 2000.0
        assert specs["x"].availability == 0.99

    def test_parse_rejects_bad_input(self):
        with pytest.raises(ValueError):
            parse_slo("nope=100", ["a"])
        with pytest.raises(ValueError):
            parse_slo("a=abc", ["a"])
        with pytest.raises(ValueError):
            parse_slo(",", ["a"])

    def test_default_ladder_rescales(self):
        ws = default_burn_windows(120.0)
        assert [w.severity for w in ws] == ["page", "page", "ticket"]
        assert ws[0].long_s == pytest.approx(10.0)
        assert ws[0].short_s == pytest.approx(2.0)
        assert ws[2].long_s == pytest.approx(120.0)


class TestTracker:
    def test_good_bad_classification(self):
        tr = SLOTracker(_spec())
        assert tr.record(500.0, t=1.0) is True
        assert tr.record(1500.0, t=2.0) is False
        tr.record_shed(t=3.0)
        assert (tr.good, tr.bad, tr.shed) == (1, 2, 1)

    def test_burn_rate_semantics(self):
        # availability 0.9 -> budget 0.1; a 20% bad stream burns at 2x
        tr = SLOTracker(_spec(availability=0.9))
        for i in range(100):
            t = 0.1 + i * 0.1
            tr.record(2000.0 if i % 5 == 0 else 10.0, t=t)
        now = 0.1 + 99 * 0.1
        assert tr.bad_fraction(60.0, now) == pytest.approx(0.2)
        assert tr.burn_rate(60.0, now) == pytest.approx(2.0)
        assert tr.error_budget_remaining(now) == pytest.approx(-1.0)
        assert tr.exhausted(now)

    def test_all_good_stream_keeps_budget(self):
        tr = SLOTracker(_spec())
        for i in range(200):
            tr.record(10.0, t=i * 0.01)
        assert tr.burn_rate(60.0, 2.0) == 0.0
        assert tr.error_budget_remaining(2.0) == pytest.approx(1.0)
        assert not tr.exhausted(2.0)
        assert tr.alerts(2.0) == []

    def test_burn_alerts_fire_deterministically(self):
        """A synthetic budget-exhausting trace must fire the fast-burn page:
        every event misses the budget -> burn rate 1/0.01 = 100x on every
        window, far above the 14.4x page threshold."""
        tr = SLOTracker(_spec())     # availability .99, window 60 s
        for i in range(600):
            tr.record(5000.0, t=i * 0.1)    # all bad, spanning 60 s
        alerts = tr.alerts(59.9)
        assert alerts, "exhausting trace must fire alerts"
        sev = {a.severity for a in alerts}
        assert "page" in sev and "ticket" in sev
        assert len(alerts) == 3              # whole ladder fires
        for a in alerts:
            assert a.burn_long >= a.threshold
            assert a.burn_short >= a.threshold
            assert a.tenant == "t"
        # determinism: replaying the identical stream gives identical alerts
        tr2 = SLOTracker(_spec())
        for i in range(600):
            tr2.record(5000.0, t=i * 0.1)
        assert [a.as_dict() for a in tr2.alerts(59.9)] == \
            [a.as_dict() for a in alerts]

    def test_multi_window_gate_needs_both(self):
        """Bad events only in the distant past: the long window still sees
        them but the short window is clean -> no page."""
        w = BurnWindow(long_s=40.0, short_s=4.0, threshold=2.0,
                       severity="page")
        tr = SLOTracker(_spec(availability=0.9), burn_windows=[w],
                        bucket_s=1.0)
        for i in range(20):
            tr.record(5000.0, t=float(i))      # bad burst at t=0..19
        for i in range(20, 40):
            tr.record(10.0, t=float(i))        # clean recovery
        assert tr.burn_rate(40.0, 39.0) > 2.0  # long window still burning
        assert tr.burn_rate(4.0, 39.0) == 0.0  # short window recovered
        assert tr.alerts(39.0) == []           # -> alert has reset

    def test_shed_counts_against_budget(self):
        tr = SLOTracker(_spec(availability=0.5))
        for i in range(10):
            tr.record_shed(t=float(i))
        assert tr.bad_fraction(60.0, 9.0) == 1.0
        assert tr.exhausted(9.0)

    def test_snapshot_emits_metrics(self):
        reg = MetricsRegistry()
        tr = SLOTracker(_spec(), registry=reg)
        tr.record(10.0, t=1.0)
        tr.record(5000.0, t=2.0)
        snap = tr.snapshot(now=2.0)
        assert snap["good"] == 1 and snap["bad"] == 1
        assert reg.find("slo.requests.good", {"tenant": "t"}).value == 1
        assert reg.find("slo.requests.bad", {"tenant": "t"}).value == 1
        assert reg.find("slo.error_budget.remaining",
                        {"tenant": "t"}) is not None
        json.dumps(snap)    # must be JSON-serializable


class TestReport:
    def _tracker(self, tenant, bad):
        tr = SLOTracker(_spec(tenant=tenant, availability=0.9))
        for i in range(50):
            late = bad and i % 2 == 0          # 50% bad -> 5x burn
            tr.record(5000.0 if late else 10.0, t=i * 0.1)
        return tr

    def test_exit_gate(self, tmp_path):
        good = self._tracker("ok", bad=False)
        burn = self._tracker("hot", bad=True)
        rep = SLOReport.from_trackers({"ok": good, "hot": burn}, now=4.9)
        assert rep.exhausted_tenants == ["hot"]
        assert not rep.ok
        assert rep.exit_code() == 1
        rep_ok = SLOReport.from_trackers({"ok": good}, now=4.9)
        assert rep_ok.ok and rep_ok.exit_code() == 0
        p = tmp_path / "slo.json"
        rep.save(str(p))
        on_disk = json.loads(p.read_text())
        assert on_disk["ok"] is False
        assert on_disk["exhausted"] == ["hot"]
        assert on_disk["tenants"]["hot"]["exhausted"] is True
