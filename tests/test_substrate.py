"""Substrate tests: optimizer, data pipeline, checkpointing, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.data import BigramSampler, JetConfig, LMDataConfig, Prefetcher, \
    jet_batch, jet_stream
from repro import ckpt


class TestOptim:
    def _quad(self):
        params = {"a": jnp.array([2.0, -3.0]), "b": jnp.array(5.0)}
        loss = lambda p: jnp.sum(p["a"] ** 2) + p["b"] ** 2
        return params, loss

    def test_adamw_converges_on_quadratic(self):
        params, loss = self._quad()
        cfg = optim.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                                total_steps=500)
        state = optim.init(params)
        for _ in range(300):
            grads = jax.grad(loss)(params)
            params, state, _ = optim.update(cfg, grads, state, params)
        assert float(loss(params)) < 1e-3

    def test_clip_by_global_norm(self):
        g = {"x": jnp.full((4,), 10.0)}
        clipped, norm = optim.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(20.0)
        assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_schedule_warmup_and_decay(self):
        cfg = optim.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                min_lr_ratio=0.1)
        assert float(optim.schedule(cfg, jnp.array(5))) == pytest.approx(0.5)
        assert float(optim.schedule(cfg, jnp.array(100))) == pytest.approx(0.1)
        mid = float(optim.schedule(cfg, jnp.array(55)))
        assert 0.1 < mid < 1.0


class TestData:
    def test_jet_batch_learnable_structure(self):
        """Class means must differ (a linear probe can beat chance)."""
        cfg = JetConfig()
        x, y = jet_batch(cfg, 512, seed=1)
        assert x.shape == (512, 64, 16) and y.shape == (512,)
        feats = x.mean(axis=1)
        mus = np.stack([feats[y == c].mean(0) for c in range(cfg.n_classes)])
        spread = np.linalg.norm(mus[:, None] - mus[None], axis=-1)
        assert spread[np.triu_indices(5, 1)].min() > 0.3

    def test_bigram_stream_entropy_floor(self):
        cfg = LMDataConfig(vocab=128, seq_len=64, branching=4)
        s = BigramSampler(cfg)
        x, y = next(s.stream(8))
        assert x.shape == (8, 64) and (y[:, :-1] == x[:, 1:]).all()

    def test_prefetcher_order_and_completion(self):
        it = iter([{"a": np.full((2,), i)} for i in range(5)])
        out = list(Prefetcher(it, depth=2))
        assert [int(b["a"][0]) for b in out] == list(range(5))


class TestCkpt:
    def _tree(self, v=0.0):
        return {"w": jnp.full((4, 4), v), "opt": {"mu": jnp.full((4, 4), v)}}

    def test_roundtrip(self, tmp_path):
        d = str(tmp_path)
        ckpt.save(d, 7, self._tree(3.0), extra={"loss": 1.5})
        tree, step, extra = ckpt.restore(d, self._tree())
        assert step == 7 and extra["loss"] == 1.5
        assert float(tree["w"][0, 0]) == 3.0

    def test_uncommitted_invisible(self, tmp_path):
        d = str(tmp_path)
        ckpt.save(d, 1, self._tree(1.0))
        ckpt.save(d, 2, self._tree(2.0))
        os.remove(os.path.join(d, "step_000000002", ckpt.COMMIT))
        assert ckpt.latest_step(d) == 1
        tree, step, _ = ckpt.restore(d, self._tree())
        assert step == 1 and float(tree["w"][0, 0]) == 1.0

    def test_retention(self, tmp_path):
        d = str(tmp_path)
        for s in range(5):
            ckpt.save(d, s, self._tree(float(s)))
        ckpt.retain(d, keep=2)
        assert ckpt.latest_step(d) == 4
        tree, step, _ = ckpt.restore(d, self._tree())
        assert step == 4

    def test_async_checkpointer(self, tmp_path):
        d = str(tmp_path)
        ac = ckpt.AsyncCheckpointer(d, keep=2)
        for s in (1, 2, 3):
            ac.maybe_save(s, self._tree(float(s)))
        ac.wait()
        assert ckpt.latest_step(d) == 3
        tree, _, _ = ckpt.restore(d, self._tree())
        assert float(tree["w"][0, 0]) == 3.0


class TestServe:
    @pytest.fixture(scope="class")
    def qmlp(self):
        from repro.quant import quantize_mlp
        rng = np.random.default_rng(0)
        ws = [rng.normal(0, 0.4, (16, 32)), rng.normal(0, 0.3, (32, 5))]
        bs = [rng.normal(0, 0.1, (32,)), rng.normal(0, 0.1, (5,))]
        xs = rng.normal(0, 1, (64, 16))
        return quantize_mlp(ws, bs, [True, False], xs)

    def test_serve_fused_equals_ref(self, qmlp):
        from repro.serve import JetServer
        from repro.quant import quantize_pow2
        rng = np.random.default_rng(1)
        x = np.asarray(quantize_pow2(rng.normal(0, 1, (64, 16)))[0])
        srv_f = JetServer(qmlp, mode="fused")
        srv_r = JetServer(qmlp, mode="ref")
        try:
            a = srv_f.infer(x)
            b = srv_r.infer(x)
            np.testing.assert_array_equal(a, b)
            assert srv_f.stats.summary()["n"] == 1
        finally:
            srv_f.close()
            srv_r.close()

    def test_batching_window_batches_requests(self, qmlp):
        from repro.serve import JetServer
        from repro.quant import quantize_pow2
        rng = np.random.default_rng(2)
        srv = JetServer(qmlp, mode="ref", max_batch=8, window_us=50_000)
        try:
            reqs = [srv.submit(np.asarray(
                quantize_pow2(rng.normal(0, 1, (64, 16)))[0]))
                for _ in range(8)]
            for r in reqs:
                assert r.event.wait(30)
            assert max(srv.stats.batch_sizes) > 1
        finally:
            srv.close()

    def test_modeled_latency_fused_wins(self, qmlp):
        from repro.serve import JetServer
        srv = JetServer(qmlp, mode="ref")
        try:
            m = srv.modeled_latency_us()
            assert m["speedup"] > 1.0
            assert m["fused_us"] < 10.0       # μs scale on the TPU target
        finally:
            srv.close()
