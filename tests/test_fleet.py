"""Fleet serving engine (repro.serve.fleet): dispatch policies, replica
accounting, merged stats, and the ServeStats edge-case fixes."""
import time

import jax
import numpy as np
import pytest

from repro.data import JetConfig, jet_batch
from repro.models import mlp as mlp_lib
from repro.serve import ServeStats
from repro.serve.fleet import FleetServer, TenantSpec


@pytest.fixture(scope="module")
def qmlp():
    jc = JetConfig(n_particles=16, n_features=8, n_classes=5, seed=0)
    params = mlp_lib.mlp_init(jax.random.key(0), 8, [16, 16, 5])
    xcal, _ = jet_batch(jc, 64, 1)
    return mlp_lib.to_quantized(params, xcal), jc


def _events(jc, n, e_in, seed=7):
    x, _ = jet_batch(jc, n, seed)
    return np.clip(np.round(x / 2.0 ** e_in), -128, 127).astype(np.int8)


class TestServeStats:
    def test_empty(self):
        s = ServeStats()
        assert s.percentile(99) == 0.0
        assert s.throughput_eps() == 0.0
        assert s.summary()["throughput_eps"] == 0.0

    def test_small_sample_tail_is_max(self):
        s = ServeStats()
        for lat in (10.0, 20.0, 30.0, 1000.0):
            s.latencies_us.append(lat)
        # 4 samples: interpolated p99 would sit below the observed max
        assert s.percentile(99) == 1000.0
        assert s.percentile(50) == pytest.approx(25.0)

    def test_large_sample_tail_interpolates(self):
        s = ServeStats()
        s.latencies_us.extend(float(i) for i in range(1, 202))
        assert s.percentile(99) < 201.0
        assert s.percentile(99) > 195.0

    def test_record_window_and_throughput(self):
        s = ServeStats()
        t0 = time.perf_counter()
        for i in range(10):
            s.record(t0 + i * 0.01, t0 + i * 0.01 + 0.005)
        assert s.t_first_submit == pytest.approx(t0)
        assert s.t_last_done == pytest.approx(t0 + 0.095)
        assert s.throughput_eps() == pytest.approx(10 / 0.095, rel=1e-6)
        assert s.summary()["throughput_eps"] > 0


class TestFleetServer:
    def test_round_robin_accounting(self, qmlp):
        q, jc = qmlp
        fleet = FleetServer([TenantSpec(name="m", qmlp=q, mode="ref",
                                        replicas=3)], policy="rr")
        try:
            xs = _events(jc, 12, q.e_in)
            for i in range(12):
                fleet.infer(xs[i])
            counts = fleet.replica_counts("m")
            assert counts == [4, 4, 4]
        finally:
            fleet.close()

    def test_least_loaded_total_accounting(self, qmlp):
        q, jc = qmlp
        fleet = FleetServer([TenantSpec(name="m", qmlp=q, mode="ref",
                                        replicas=4)], policy="least_loaded")
        try:
            xs = _events(jc, 20, q.e_in)
            reqs = [fleet.submit(xs[i]) for i in range(20)]
            for r in reqs:
                assert r.event.wait(30)
            counts = fleet.replica_counts("m")
            assert sum(counts) == 20
            assert len(counts) == 4
        finally:
            fleet.close()

    def test_results_match_single_server(self, qmlp):
        q, jc = qmlp
        fleet = FleetServer([TenantSpec(name="m", qmlp=q, mode="ref",
                                        replicas=2)])
        single = FleetServer([TenantSpec(name="m", qmlp=q, mode="ref",
                                         replicas=1)])
        try:
            xs = _events(jc, 6, q.e_in)
            for i in range(6):
                a = fleet.infer(xs[i])
                b = single.infer(xs[i])
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        finally:
            fleet.close()
            single.close()

    def test_merged_stats_and_summary(self, qmlp):
        q, jc = qmlp
        fleet = FleetServer([TenantSpec(name="m", qmlp=q, mode="ref",
                                        replicas=2)])
        try:
            xs = _events(jc, 8, q.e_in)
            for i in range(8):
                fleet.infer(xs[i])
            st = fleet.stats("m")
            assert len(st.latencies_us) == 8
            assert st.percentile(50) > 0
            assert st.throughput_eps() > 0
            s = fleet.summary()
            assert s["fleet"]["n"] == 8
            assert s["fleet"]["replicas"] == 2
            assert s["tenants"]["m"]["dispatched"] and \
                sum(s["tenants"]["m"]["dispatched"]) == 8
        finally:
            fleet.close()

    def test_multi_tenant_routing(self, qmlp):
        q, jc = qmlp
        fleet = FleetServer([TenantSpec(name="a", qmlp=q, mode="ref",
                                        replicas=1),
                             TenantSpec(name="b", qmlp=q, mode="ref",
                                        replicas=2)])
        try:
            xs = _events(jc, 6, q.e_in)
            for i in range(4):
                fleet.infer(xs[i], tenant="a")
            for i in range(6):
                fleet.infer(xs[i], tenant="b")
            assert sum(fleet.replica_counts("a")) == 4
            assert sum(fleet.replica_counts("b")) == 6
            # tenant=None covers the whole fleet, matching stats(None)
            assert sum(fleet.replica_counts()) == 10
            assert len(fleet.replica_counts()) == 3
            assert fleet.stats().summary()["n"] == 10
            assert fleet.num_replicas == 3
            with pytest.raises(KeyError):
                fleet.submit(xs[0], tenant="nope")
        finally:
            fleet.close()

    def test_infer_batch_scatter_gather(self, qmlp):
        """Micro-batched dispatch: results in submission order and equal to
        per-event dispatch; the scatter covers every replica."""
        q, jc = qmlp
        fleet = FleetServer([TenantSpec(name="m", qmlp=q, mode="ref",
                                        replicas=3)])
        single = FleetServer([TenantSpec(name="m", qmlp=q, mode="ref",
                                         replicas=1)])
        try:
            xs = _events(jc, 9, q.e_in)
            br = fleet.infer_batch(xs)
            assert br.results.shape[0] == 9
            assert br.n == 9
            assert br.replica_counts == [3, 3, 3]
            assert sum(fleet.replica_counts("m")) == 9
            assert br.percentile(50) > 0 and br.percentile(99) > 0
            assert br.throughput_eps > 0
            assert br.summary()["n"] == 9
            for i in range(9):
                np.testing.assert_array_equal(
                    np.asarray(br.results[i]), np.asarray(single.infer(xs[i])))
        finally:
            fleet.close()
            single.close()

    def test_infer_batch_smaller_than_fleet(self, qmlp):
        q, jc = qmlp
        fleet = FleetServer([TenantSpec(name="m", qmlp=q, mode="ref",
                                        replicas=4)])
        try:
            xs = _events(jc, 2, q.e_in)
            br = fleet.infer_batch(xs)
            assert br.n == 2 and br.results.shape[0] == 2
            assert sum(br.replica_counts) == 2
            assert fleet.submit_batch([]) == []
            empty = fleet.infer_batch([])
            assert empty.n == 0 and empty.results.shape[0] == 0
            assert empty.replica_counts == [0, 0, 0, 0]
            with pytest.raises(KeyError):
                fleet.submit_batch(xs, tenant="nope")
            with pytest.raises(KeyError):
                fleet.infer_batch(xs, tenant="nope")
        finally:
            fleet.close()

    def test_bad_args(self, qmlp):
        q, _ = qmlp
        with pytest.raises(ValueError):
            FleetServer([])
        with pytest.raises(ValueError):
            FleetServer([TenantSpec(name="m", qmlp=q, replicas=0)])
        with pytest.raises(ValueError):
            FleetServer([TenantSpec(name="m", qmlp=q)], policy="magic")
        with pytest.raises(ValueError):
            FleetServer([TenantSpec(name="m", qmlp=q),
                         TenantSpec(name="m", qmlp=q)])


class TestFleetTelemetry:
    def test_dispatch_metrics_recorded(self, qmlp):
        q, jc = qmlp
        fleet = FleetServer([TenantSpec(name="m", qmlp=q, mode="ref",
                                        replicas=2)])
        try:
            xs = _events(jc, 8, q.e_in)
            fleet.infer_batch(xs)
            for i in range(4):
                fleet.infer(xs[i])
            reg = fleet.registry
            disp = reg.all("fleet.replica.dispatched")
            assert sum(c.value for c in disp) == 12
            depths = reg.all("fleet.replica.queue_depth")
            assert len(depths) == 2
            lat = reg.find("fleet.request.latency_us", {"tenant": "m"})
            assert lat is not None and lat.count == 12
            assert lat.quantile(0.5) > 0
            assert reg.find("fleet.batch.size", {"tenant": "m"}).count == 1
            oh = reg.find("fleet.dispatch.overhead_us", {"tenant": "m"})
            assert oh is not None and oh.count == 5   # 1 batch + 4 singles
            s = fleet.summary()["tenants"]["m"]
            assert s["rolling_p50_us"] > 0
            assert s["rolling_p99_us"] >= s["rolling_p50_us"]
        finally:
            fleet.close()

    def test_adaptive_scatter_skews_away_from_backlog(self, qmlp):
        """A replica with a queue backlog gets a proportionally smaller
        slice; equal queues reduce to the balanced split."""
        q, _ = qmlp
        fleet = FleetServer([TenantSpec(name="m", qmlp=q, mode="ref",
                                        replicas=2)])
        try:
            servers = fleet._servers["m"]
            # Freeze the workers so the staged backlog is stable.
            for s in servers:
                s._stop.set()
            for s in servers:
                s._thread.join(timeout=5)
            assert [len(ix) for ix in fleet._slices("m", 10)] == [5, 5]
            for _ in range(4):
                servers[0]._q.put(object())
            # weights 1/5 vs 1 -> shares [1.67, 8.33] -> [2, 8]
            assert [len(ix) for ix in fleet._slices("m", 10)] == [2, 8]
            # slices stay contiguous and cover the batch in order
            np.testing.assert_array_equal(
                np.concatenate(fleet._slices("m", 10)), np.arange(10))
        finally:
            fleet.close()

    def test_batch_spans_in_tracer(self, qmlp):
        from repro.obs import Tracer
        q, jc = qmlp
        tr = Tracer()
        fleet = FleetServer([TenantSpec(name="m", qmlp=q, mode="ref",
                                        replicas=2)], tracer=tr)
        try:
            fleet.infer_batch(_events(jc, 6, q.e_in))
        finally:
            fleet.close()
        spans = tr.spans("fleet")
        names = {e["name"] for e in spans}
        assert "infer_batch[6]" in names
        assert any(n.startswith("slice[") for n in names)
        assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in spans)

    def test_drift_snapshot_and_telemetry(self, qmlp):
        import json as _json

        from repro.core import layerspec
        q, jc = qmlp
        fleet = FleetServer([TenantSpec(name="m", qmlp=q, mode="ref",
                                        replicas=2,
                                        model_spec=layerspec.jsc_m())])
        try:
            xs = _events(jc, 8, q.e_in)
            fleet.infer_batch(xs)
            snap = fleet.telemetry_snapshot(tier_s=True)
        finally:
            fleet.close()
        drift = snap["drift"]
        # serving path: per-replica ratios populated, hugely inflated vs
        # the modeled VEK280 (CPU interpret mode) — informational only
        entries = drift["serve.latency_us"]["entries"]
        assert set(entries) == {"m#0", "m#1"}
        assert all(e["ratio"] is not None and e["ratio"] > 1.0
                   for e in entries.values())
        # model path: Tier-A analytic vs Tier-S simulated, tight agreement
        model = drift["model.latency_ns"]["entries"]["m"]
        assert model["ratio"] == pytest.approx(1.0, abs=0.05)
        assert drift["model.latency_ns"]["mape"] < 0.05
        _json.dumps(snap)   # whole bundle must be JSON-serializable
        assert fleet.drift.flagged(10.0, "serve.latency_us")


class TestLoadAndSLO:
    """Open-loop ingress: offer/shed accounting, SLO trackers, and the
    workload driver against a real (ref-mode) fleet."""

    def test_offer_admits_all_without_depth(self, qmlp):
        q, jc = qmlp
        fleet = FleetServer([TenantSpec(name="m", qmlp=q, mode="ref",
                                        replicas=2)])
        try:
            xs = _events(jc, 8, q.e_in)
            reqs = [fleet.offer(xs[i]) for i in range(8)]
            assert all(r is not None for r in reqs)
            for r in reqs:
                assert r.event.wait(timeout=30)
            reg = fleet.registry
            assert reg.find("load.offered", {"tenant": "m"}).value == 8
            assert reg.find("load.admitted", {"tenant": "m"}).value == 8
            assert reg.find("load.shed", {"tenant": "m"}).value == 0
            with pytest.raises(KeyError):
                fleet.offer(xs[0], tenant="ghost")
        finally:
            fleet.close()

    def test_offer_sheds_at_admission_depth(self, qmlp):
        q, jc = qmlp
        from repro.obs.slo import SLOSpec
        slo = SLOSpec(tenant="m", p99_latency_budget_ns=1e6,
                      availability=0.9, window_s=60.0)
        # depth 0: every replica queue is always "full" -> shed everything
        fleet = FleetServer([TenantSpec(name="m", qmlp=q, mode="ref",
                                        replicas=1)],
                            slos={"m": slo}, admission_depth=0)
        try:
            xs = _events(jc, 5, q.e_in)
            assert all(fleet.offer(xs[i]) is None for i in range(5))
            reg = fleet.registry
            assert reg.find("load.offered", {"tenant": "m"}).value == 5
            assert reg.find("load.admitted", {"tenant": "m"}).value == 0
            assert reg.find("load.shed", {"tenant": "m"}).value == 5
            tr = fleet.slo_trackers["m"]
            assert tr.shed == 5
            rep = fleet.slo_snapshot()
            assert rep.tenants["m"]["shed"] == 5
            assert rep.meta["admission_depth"] == 0
        finally:
            fleet.close()

    def test_slo_validation_at_construction(self, qmlp):
        q, _ = qmlp
        from repro.obs.slo import SLOSpec
        spec = SLOSpec(tenant="ghost", p99_latency_budget_ns=1e6,
                       availability=0.99, window_s=60.0)
        with pytest.raises(ValueError, match="unknown tenant"):
            FleetServer([TenantSpec(name="m", qmlp=q, mode="ref")],
                        slos={"ghost": spec})
        with pytest.raises(ValueError, match="names tenant"):
            FleetServer([TenantSpec(name="m", qmlp=q, mode="ref")],
                        slos={"m": spec})

    def test_completion_feeds_slo_and_queue_wait(self, qmlp):
        q, jc = qmlp
        from repro.obs.slo import SLOSpec
        slo = SLOSpec(tenant="m", p99_latency_budget_ns=1e12,
                      availability=0.9, window_s=60.0)
        fleet = FleetServer([TenantSpec(name="m", qmlp=q, mode="ref",
                                        replicas=2)], slos={"m": slo})
        try:
            xs = _events(jc, 10, q.e_in)
            reqs = [fleet.offer(xs[i]) for i in range(10)]
            for r in reqs:
                assert r.event.wait(timeout=30)
            # generous 1 ms p99 budget in ns -> every request is good
            tr = fleet.slo_trackers["m"]
            assert tr.good == 10 and tr.bad == 0
            wait = fleet.registry.find("fleet.request.queue_wait_us",
                                       {"tenant": "m"})
            assert wait is not None and wait.count == 10
            assert wait.min >= 0.0
            snap = fleet.telemetry_snapshot(drift=False)
            assert snap["slo"]["tenants"]["m"]["good"] == 10
            assert snap["slo"]["ok"] is True
        finally:
            fleet.close()

    def test_workload_drive_on_real_fleet(self, qmlp):
        q, jc = qmlp
        from repro.serve import workload
        fleet = FleetServer([TenantSpec(name="m", qmlp=q, mode="ref",
                                        replicas=2)])
        try:
            xs = _events(jc, 16, q.e_in)
            dr = workload.drive(fleet, list(xs), workload.poisson(2000.0),
                                tenant="m", seed=1)
            assert dr.offered == 16
            assert dr.admitted == 16 and dr.shed == 0
            assert dr.admitted_idx == list(range(16))
            for r in dr.requests:
                assert r.event.wait(timeout=30)
            assert dr.offered_eps > 0
            assert dr.wall_s > 0
        finally:
            fleet.close()
