"""Property-based tests of the DSE's structural invariants (hypothesis):
whatever chain it is given, every returned design must be physically legal
on the AIE array and internally consistent."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import aie_arch, dse
from repro.core.layerspec import LayerSpec, ModelSpec, deepsets
from repro.core.mapping import cascade_compatible
from repro.core.placement import east_adjacent


@st.composite
def mlp_chains(draw):
    """Random MM chains with chained shapes (layer i's N == layer i+1's K)."""
    n_layers = draw(st.integers(1, 6))
    m = draw(st.sampled_from([8, 16, 32, 64]))
    dims = [draw(st.sampled_from([5, 8, 16, 21, 32, 64, 128]))
            for _ in range(n_layers + 1)]
    layers = tuple(
        LayerSpec(kind="mm", M=m, K=dims[i], N=dims[i + 1],
                  bias=draw(st.booleans()), relu=i < n_layers - 1,
                  name=f"l{i}")
        for i in range(n_layers))
    return ModelSpec(layers, name="rand")


class TestDSEInvariants:
    @settings(max_examples=25, deadline=None)
    @given(model=mlp_chains())
    def test_returned_design_is_legal(self, model):
        r = dse.explore(model)
        if r is None:
            return                      # infeasible chains are allowed
        rects = r.placement.rects
        # 1. inside the array, no overlaps
        for rect in rects:
            assert 0 <= rect.r0 and rect.r1 <= aie_arch.ARRAY_ROWS
            assert 0 <= rect.c0 and rect.c1 <= aie_arch.ARRAY_COLS
        for i in range(len(rects)):
            for j in range(i + 1, len(rects)):
                assert not rects[i].overlaps(rects[j]), (i, j)
        # 2. budgets
        assert r.mapping.total_tiles <= aie_arch.NUM_TILES
        assert r.mapping.plio_ports_needed() <= aie_arch.PLIO_PORTS
        # 3. every cascade edge is both mapping-compatible and east-adjacent
        maps = r.mapping.mappings
        for i, is_cas in enumerate(r.placement.cascade_links()):
            if is_cas:
                assert cascade_compatible(maps[i], maps[i + 1])
                agg = (maps[i].layer.kind == "agg"
                       or maps[i + 1].layer.kind == "agg")
                assert east_adjacent(rects[i], rects[i + 1],
                                     exact_rows=not agg)
        # 4. latency decomposition is consistent
        lb = r.latency
        assert len(lb.comp) == model.num_layers
        assert len(lb.comm) == model.num_layers - 1
        assert lb.total > 0 and lb.total < 1e9

    @settings(max_examples=15, deadline=None)
    @given(model=mlp_chains())
    def test_cascade_never_loses_to_forced_dma(self, model):
        """The search space with cascade edges available is a superset of
        the forced-DMA space, so its optimum can never be worse."""
        a = dse.explore(model)
        b = dse.explore(model, force_dma=True)
        if a is not None and b is not None:
            assert a.latency.total <= b.latency.total + 1e-6

    @settings(max_examples=10, deadline=None)
    @given(m=st.sampled_from([16, 32, 64]),
           f=st.sampled_from([8, 16, 21]),
           width=st.sampled_from([16, 32, 64]))
    def test_deepsets_chains_explore(self, m, f, width):
        model = deepsets(m, f, [width, width], [width, 5])
        r = dse.explore(model)
        assert r is not None
        # the aggregation edge constraint: producer has C == 1
        agg_idx = next(i for i, l in enumerate(model.layers)
                       if l.kind == "agg")
        assert r.mapping.mappings[agg_idx - 1].C == 1


def _oracle_front(keys):
    """O(n^2) reference of both filters' shared contract: one survivor per
    key, keys weakly dominated by any *distinct* key dropped."""
    uniq = set(keys)
    return sorted(k for k in uniq
                  if not any(o != k and all(a <= b for a, b in zip(o, k))
                             for o in uniq))


class TestParetoKernels:
    """The vectorized dominance kernels must agree with an O(n^2) oracle
    on both sides of the ``_PARETO_VECTOR_MIN`` scalar/vector cutover
    (hypothesis draws sizes spanning it)."""

    @settings(max_examples=40, deadline=None)
    @given(pts=st.lists(st.tuples(st.integers(0, 12), st.integers(0, 12)),
                        min_size=0, max_size=200))
    def test_pareto_front_matches_oracle(self, pts):
        got = dse.pareto_front(pts, lambda p: p)
        assert sorted(got) == _oracle_front(pts)
        # canonical staircase: primary strictly ascending, secondary
        # strictly descending (ties on either axis cannot both survive)
        for (a1, b1), (a2, b2) in zip(got, got[1:]):
            assert a1 < a2 and b2 < b1

    @settings(max_examples=40, deadline=None)
    @given(pts=st.lists(st.tuples(st.integers(0, 8), st.integers(0, 8),
                                  st.integers(0, 8)),
                        min_size=0, max_size=200))
    def test_pareto_front_nd_matches_oracle(self, pts):
        assert sorted(dse.pareto_front_nd(pts, lambda p: p)) == \
            _oracle_front(pts)

    @settings(max_examples=25, deadline=None)
    @given(pts=st.lists(st.tuples(st.floats(0, 100, allow_nan=False),
                                  st.floats(0, 100, allow_nan=False),
                                  st.floats(0, 100, allow_nan=False)),
                        min_size=64, max_size=300))
    def test_pareto_front_nd_vector_path_floats(self, pts):
        # n >= 64 forces the numpy kernel; the oracle must still agree
        assert sorted(dse.pareto_front_nd(pts, lambda p: p)) == \
            _oracle_front(pts)

    def test_unvectorizable_keys_fall_back_to_scalar(self):
        # string keys cannot be lifted to a float matrix even at vector
        # size; the scalar loop must serve them with identical semantics
        pts = [("b", "b"), ("a", "a"), ("a", "c"), ("c", "a")] * 20
        assert sorted(dse.pareto_front_nd(pts, lambda p: p)) == \
            _oracle_front(pts)

    def test_nan_keys_fall_back_to_scalar(self):
        pts = ([(1.0, float("nan"), 2.0)] * 40
               + [(0.0, 0.0, 0.0), (2.0, 2.0, 2.0)] * 20)
        got = dse.pareto_front_nd(pts, lambda p: p)
        assert (0.0, 0.0, 0.0) in got
