"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles.

INT8 pipelines are bit-exact, so every comparison is exact equality.
Hypothesis sweeps shapes; fixed seeds keep runs reproducible.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.quant import (QuantizedMLP, quantize_mlp, quantize_pow2,
                         dequantize_pow2, requantize_shift)
from repro.kernels.mm_int8 import mm_int8, mm_int8_ref
from repro.kernels.cascade_mlp import (cascade_mlp, cascade_mlp_ref, deepsets,
                                       deepsets_ref, mlp_unfused)
from repro.kernels.global_agg import global_agg, global_agg_ref


def _rand_int8(rng, shape):
    return jnp.asarray(rng.integers(-128, 128, shape), jnp.int8)


class TestMMInt8:
    @given(m=st.sampled_from([1, 7, 8, 32, 64, 100, 128]),
           k=st.sampled_from([5, 16, 21, 32, 64, 130]),
           n=st.sampled_from([5, 10, 32, 64, 128, 200]),
           bias=st.booleans(), relu=st.booleans(),
           shift=st.sampled_from([0, 3, 7]))
    @settings(max_examples=25, deadline=None)
    def test_sweep_vs_ref(self, m, k, n, bias, relu, shift):
        rng = np.random.default_rng(m * 1000 + k * 10 + n)
        x = _rand_int8(rng, (m, k))
        w = _rand_int8(rng, (k, n))
        b = (jnp.asarray(rng.integers(-5000, 5000, (n,)), jnp.int32)
             if bias else None)
        got = mm_int8(x, w, b, shift=shift, relu=relu, interpret=True)
        want = mm_int8_ref(x, w, b, shift=shift, relu=relu)
        assert got.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_int32_raw_output(self):
        rng = np.random.default_rng(0)
        x, w = _rand_int8(rng, (16, 32)), _rand_int8(rng, (32, 16))
        got = mm_int8(x, w, out_int8=False, interpret=True)
        want = mm_int8_ref(x, w, out_int8=False)
        assert got.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_requant_saturates(self):
        x = jnp.full((8, 128), 127, jnp.int8)
        w = jnp.full((128, 8), 127, jnp.int8)
        out = mm_int8(x, w, shift=0, interpret=True)
        assert int(out.max()) == 127      # saturated, not wrapped


class TestCascadeMLP:
    def _random_qmlp(self, rng, dims, m):
        ws = [rng.normal(0, 0.4, (dims[i], dims[i + 1]))
              for i in range(len(dims) - 1)]
        bs = [rng.normal(0, 0.1, (d,)) for d in dims[1:]]
        relus = [True] * (len(ws) - 1) + [False]
        xs = rng.normal(0, 1, (m, dims[0]))
        q = quantize_mlp(ws, bs, relus, xs)
        xq, _ = quantize_pow2(xs)
        return q, xq

    @given(depth=st.integers(2, 6),
           m=st.sampled_from([8, 32, 64, 96]),
           seed=st.integers(0, 5))
    @settings(max_examples=12, deadline=None)
    def test_fused_equals_ref(self, depth, m, seed):
        rng = np.random.default_rng(seed)
        dims = [int(rng.choice([16, 21, 32, 64]))] + \
               [int(rng.choice([32, 64, 128])) for _ in range(depth - 1)] + [5]
        q, xq = self._random_qmlp(rng, dims, m)
        got = cascade_mlp(xq, q, interpret=True)
        want = cascade_mlp_ref(xq, q)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_fused_equals_unfused(self):
        """The cascade (fused) kernel and the per-layer (DMA-analogue)
        baseline must produce identical bits — same contract as the paper's
        cascade vs DMA designs computing the same network."""
        rng = np.random.default_rng(3)
        q, xq = self._random_qmlp(rng, [16, 64, 64, 32, 5], 64)
        fused = cascade_mlp(xq, q, interpret=True)
        unfused = mlp_unfused(xq, q, interpret=True)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))

    def test_quantization_tracks_float(self):
        """End-to-end INT8 output must approximate the float MLP."""
        rng = np.random.default_rng(1)
        dims = [16, 64, 32, 8]
        ws = [rng.normal(0, 0.4, (dims[i], dims[i + 1])) for i in range(3)]
        bs = [rng.normal(0, 0.1, (d,)) for d in dims[1:]]
        xs = rng.normal(0, 1, (64, 16))
        q = quantize_mlp(ws, bs, [True, True, False], xs)
        xq, _ = quantize_pow2(xs)
        got = cascade_mlp(xq, q, interpret=True)
        f = dequantize_pow2(got, q.layers[-1].e_out)
        ref = np.maximum(xs @ ws[0] + bs[0], 0)
        ref = np.maximum(ref @ ws[1] + bs[1], 0)
        ref = ref @ ws[2] + bs[2]
        err = np.abs(np.asarray(f) - ref).mean() / (np.abs(ref).mean() + 1e-9)
        assert err < 0.12, err


class TestGlobalAgg:
    @given(m=st.sampled_from([4, 8, 16, 32, 64]),
           f=st.sampled_from([5, 32, 40, 64, 130]),
           op=st.sampled_from(["sum", "mean"]),
           impl=st.sampled_from(["mac", "extract_add"]))
    @settings(max_examples=20, deadline=None)
    def test_sweep_vs_ref(self, m, f, op, impl):
        rng = np.random.default_rng(m + f)
        x = _rand_int8(rng, (m, f))
        got = global_agg(x, op=op, impl=impl, interpret=True)
        want = global_agg_ref(x, op=op)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_mac_equals_extract_add(self):
        """Both implementations are the same function (Table 4's comparison
        is about speed, not semantics)."""
        rng = np.random.default_rng(7)
        x = _rand_int8(rng, (64, 64))
        a = global_agg(x, op="sum", impl="mac", interpret=True)
        b = global_agg(x, op="sum", impl="extract_add", interpret=True)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestDeepSets:
    @given(m=st.sampled_from([16, 32, 64]),
           agg=st.sampled_from(["mean", "sum"]),
           seed=st.integers(0, 3))
    @settings(max_examples=10, deadline=None)
    def test_fused_deepsets_vs_ref(self, m, agg, seed):
        rng = np.random.default_rng(seed)
        phi_dims = [21, 32, 32]
        phi_w = [rng.normal(0, 0.4, (phi_dims[i], phi_dims[i + 1]))
                 for i in range(2)]
        phi_b = [rng.normal(0, 0.1, (d,)) for d in phi_dims[1:]]
        xs = rng.normal(0, 1, (m, 21))
        phi = quantize_mlp(phi_w, phi_b, [True, True], xs)
        h = np.maximum(xs @ phi_w[0] + phi_b[0], 0)
        h = np.maximum(h @ phi_w[1] + phi_b[1], 0).mean(0, keepdims=True)
        rho_w = [rng.normal(0, 0.3, (32, 10))]
        rho_b = [rng.normal(0, 0.1, (10,))]
        rho = quantize_mlp(rho_w, rho_b, [False], h)
        xq, _ = quantize_pow2(xs)
        got = deepsets(xq, phi, rho, agg=agg, interpret=True)
        want = deepsets_ref(xq, phi, rho, agg=agg)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestQuant:
    @given(shift=st.integers(0, 10), seed=st.integers(0, 20))
    @settings(max_examples=20, deadline=None)
    def test_requant_shift_round_half_away(self, shift, seed):
        rng = np.random.default_rng(seed)
        acc = jnp.asarray(rng.integers(-2**20, 2**20, (64,)), jnp.int32)
        got = requantize_shift(acc, shift)
        # reference rounds HALF AWAY FROM ZERO (AIE SRS semantics) —
        # np.round would be banker's rounding and differ on exact halves
        a = np.asarray(acc) / (2 ** shift)
        want = np.clip(np.where(a >= 0, np.floor(a + 0.5),
                                np.ceil(a - 0.5)),
                       -128, 127).astype(np.int8)
        np.testing.assert_array_equal(np.asarray(got), want)

    @given(seed=st.integers(0, 20))
    @settings(max_examples=10, deadline=None)
    def test_quantize_roundtrip(self, seed):
        rng = np.random.default_rng(seed)
        x = rng.normal(0, float(rng.uniform(0.01, 10)), (32, 32))
        q, e = quantize_pow2(x)
        err = np.abs(np.asarray(dequantize_pow2(q, e)) - x).max()
        assert err <= 2.0 ** e        # within one quantization step
