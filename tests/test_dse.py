"""Tier-A tests: mapping, placement, and the §5.2 DSE."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import aie_arch, layerspec as L
from repro.core.dse import explore
from repro.core.layerspec import LayerSpec, ModelSpec
from repro.core.mapping import (Mapping, ModelMapping, cascade_compatible,
                                enumerate_mappings)
from repro.core.placement import east_adjacent, max_manhattan, place, Rect


class TestMapping:
    def test_per_aie_shape_padding(self):
        m = Mapping(A=4, B=2, C=1, layer=LayerSpec(kind="mm", M=32, K=21, N=32))
        assert m.H1 == 8            # 32/4, already a multiple of 2*B_M
        assert m.W1 == 16           # ceil(21/2)=11 -> pad to B_K=8 multiple
        assert m.W2 == 32

    def test_rows_cols_layout(self):
        m = Mapping(A=2, B=3, C=2, layer=LayerSpec(kind="mm", M=64, K=64, N=64))
        assert m.rows == 4 and m.cols == 3 and m.tiles == 12

    def test_cascade_rule(self):
        l1 = LayerSpec(kind="mm", M=64, K=64, N=64)
        l2 = LayerSpec(kind="mm", M=64, K=64, N=32)
        a = Mapping(A=4, B=2, C=1, layer=l1)
        b = Mapping(A=4, B=4, C=1, layer=l2)
        assert cascade_compatible(a, b)                 # A=A', C=C'=1
        c = Mapping(A=2, B=2, C=1, layer=l2)
        assert not cascade_compatible(a, c)             # A mismatch
        d = Mapping(A=4, B=2, C=2, layer=l2)
        assert not cascade_compatible(a, d)             # C' != 1

    @given(m=st.sampled_from([8, 16, 32, 64, 128]),
           k=st.sampled_from([16, 21, 32, 64, 128]),
           n=st.sampled_from([5, 10, 32, 64, 128]))
    @settings(max_examples=30, deadline=None)
    def test_enumeration_invariants(self, m, k, n):
        layer = LayerSpec(kind="mm", M=m, K=k, N=n)
        seen = set()
        for mp in enumerate_mappings(layer, aie_arch.NUM_TILES):
            key = (mp.A, mp.B, mp.C)
            assert key not in seen
            seen.add(key)
            # powers of two
            for v in key:
                assert v & (v - 1) == 0
            assert mp.rows <= aie_arch.ARRAY_ROWS
            # per-AIE shape covers the layer
            assert mp.A * mp.H1 >= m
            assert mp.B * mp.W1 >= k
            assert mp.C * mp.W2 >= n
        assert seen    # never empty


class TestPlacement:
    def _mm(self, shapes):
        layers = []
        k = shapes[0][1]
        for i, (mshape, kk, n) in enumerate(shapes):
            layers.append(LayerSpec(kind="mm", M=mshape, K=kk, N=n, name=f"l{i}"))
        return layers

    def test_no_overlap_and_in_bounds(self):
        model = L.synthetic_mlp(64, 6)
        maps = []
        for layer in model.layers:
            maps.append(next(iter(enumerate_mappings(layer, 32))))
        mm = ModelMapping(model=model, mappings=tuple(maps))
        pl = place(mm)
        assert pl is not None
        seen = set()
        for r in pl.rects:
            assert 0 <= r.r0 and r.r1 <= aie_arch.ARRAY_ROWS
            assert 0 <= r.c0 and r.c1 <= aie_arch.ARRAY_COLS
            for t in r.tiles():
                assert t not in seen
                seen.add(t)

    def test_east_adjacency_gives_cascade(self):
        model = L.synthetic_mlp(32, 3)
        maps = tuple(Mapping(A=4, B=2, C=1, layer=l) for l in model.layers)
        mm = ModelMapping(model=model, mappings=maps)
        pl = place(mm)
        assert pl is not None
        assert pl.cascade_links() == [True, True]

    def test_manhattan(self):
        a = Rect(0, 0, 2, 2)
        b = Rect(0, 2, 2, 2)
        assert east_adjacent(a, b)
        assert max_manhattan(a, b) == 1 + 2   # row delta 1, col delta 2


class TestDSE:
    def test_respects_tile_budget(self):
        r = explore(L.synthetic_mlp(64, 12, bias_relu=True))
        assert r is not None
        assert r.mapping.total_tiles <= aie_arch.NUM_TILES

    def test_respects_plio_budget(self):
        r = explore(L.jsc_m())
        assert r is not None
        assert r.mapping.plio_ports_needed() <= aie_arch.PLIO_PORTS

    def test_prefers_cascade(self):
        """On the paper's workloads the DSE should cascade every edge."""
        for wl in ("JSC-M", "Deepsets-32"):
            r = explore(L.REALISTIC_WORKLOADS[wl]())
            links = r.placement.cascade_links()
            assert all(links), (wl, links)

    def test_cascade_beats_dma_ablation(self):
        for wl in ("JSC-M", "JSC-XL", "Deepsets-64"):
            cas = explore(L.REALISTIC_WORKLOADS[wl]())
            dma = explore(L.REALISTIC_WORKLOADS[wl](), force_dma=True)
            assert cas.latency.total < dma.latency.total

    def test_128_cascade_constraint_limits_parallelism(self):
        """Paper §6.3: for 128^3 the C=1 constraint caps μ-ORCA at an
        8x4x1-style array (32 tiles/layer), unlike SSR's 4x4x4."""
        r = explore(L.synthetic_mlp(128, 2, bias_relu=True))
        assert r is not None
        for m in r.mapping.mappings:
            assert m.C == 1     # the cascade constraint the paper describes
        # the PLIO-facing first layer is capped (paper's 8x4x1 point);
        # interior layers may grow B since only cascade feeds them.
        assert r.mapping.mappings[0].tiles <= 64

    def test_budget_claims(self):
        """Paper: within 1 μs, >12 layers of 32^3 or >4 layers of 64^3."""
        assert explore(L.synthetic_mlp(32, 12, bias_relu=True)).latency_ns < 1000
        assert explore(L.synthetic_mlp(64, 4, bias_relu=True)).latency_ns < 1000

    def test_deepsets_under_budget(self):
        """Paper: 0.93 μs for the 6-layer DeepSets (Deepsets-64); 6/7
        realistic workloads < 1 μs with Deepsets-64-d at ~1.1 μs."""
        r = explore(L.deepsets_64())
        assert r.latency_ns < 1000
        r2 = explore(L.deepsets_64_d())
        assert 900 < r2.latency_ns < 1300

    def test_dse_beats_naive_mapping(self):
        """DSE must beat a naive 1-AIE-per-layer design."""
        model = L.jsc_xl()
        naive_maps = tuple(Mapping(A=1, B=1, C=1, layer=l) for l in model.layers)
        mm = ModelMapping(model=model, mappings=naive_maps)
        pl = place(mm)
        from repro.core.perfmodel import end_to_end_cycles
        naive = end_to_end_cycles(pl)
        best = explore(model)
        assert best.latency.total < naive.total
