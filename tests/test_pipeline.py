"""GPipe pipeline over a mesh axis: correctness vs sequential execution.

Runs in a subprocess with 4 host devices (the main test process must keep
the default single-device jax)."""
import subprocess
import sys
import textwrap

import jax
import pytest

# Version gate instead of a CI ignore-list entry: the subprocess script
# builds its mesh via repro.launch.mesh.make_mesh, which needs
# jax.sharding.AxisType — outside the requirements-dev.txt jax pin. The
# probe re-enables the file automatically once the pin is reconciled.
if not hasattr(jax.sharding, "AxisType"):
    pytest.skip("jax pin lacks jax.sharding.AxisType (make_mesh needs a "
                "newer jax; reconcile the requirements-dev.txt pin)",
                allow_module_level=True)

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.distributed.pipeline import pipeline, stack_stage_params
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("pp",))
    rng = np.random.default_rng(0)
    D = 16
    stages = [{"w": jnp.asarray(rng.normal(0, 0.5, (D, D)), jnp.float32)}
              for _ in range(4)]

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])

    stacked = stack_stage_params(stages)
    x = jnp.asarray(rng.normal(size=(8, D)), jnp.float32)

    run = pipeline(stage_fn, mesh, "pp", n_micro=4)
    got = run(stacked, x)

    ref = x
    for p in stages:
        ref = stage_fn(p, ref)
    err = float(jnp.max(jnp.abs(got - ref)))
    assert err < 1e-5, f"pipeline diverges: {err}"
    print("PIPELINE_OK", err)
""")


def test_pipeline_matches_sequential():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
